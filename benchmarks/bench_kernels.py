"""Kernel micro-benchmarks (interpret mode -> correctness + VMEM/footprint
accounting; wall numbers are CPU-interpret and NOT TPU times).

Derived columns report the *structural* quantities that determine TPU
performance: VMEM working set per grid step and HBM bytes per output tile
for the chosen BlockSpecs (what you reason about on the lowered IR).

``--sweep`` (or env ``ITA_BENCH_SWEEP=1``) runs a (block_q, block_kv)
grid over the fused onepass/decode backends **and** a (block_m, block_n,
block_k) grid over ``int8_matmul``, reporting wall time plus the
structural VMEM/DMA columns per cell — the data behind the per-backend
defaults recorded in ``repro.kernels.common.BLOCK_DEFAULTS`` (the
dispatch/ops defaults; override per call with ``block_*=`` arguments).
"""

import os
import time

import numpy as np


def vmem_rows():
    rows = []
    # ita_attention onepass: q(bq,d)i8 + k/v(bkv,d)i8 + acc(bq,d)f32 +
    # stats 2*(bq,1)i32 + logits tile (bq,bkv)i32
    for bq, bkv, d in [(128, 128, 64), (128, 128, 128), (256, 512, 128)]:
        vmem = bq * d + 2 * bkv * d + bq * d * 4 + 2 * bq * 4 \
            + bq * bkv * 4
        rows.append((f"kernels/ita_attention_vmem_bytes/bq{bq}_bkv{bkv}_d{d}",
                     vmem))
    # int8 matmul: x(bm,bk) + w(bk,bn) + acc(bm,bn)i32
    for bm, bn, bk in [(256, 128, 128), (1024, 128, 512)]:
        vmem = bm * bk + bk * bn + bm * bn * 4
        rows.append((f"kernels/int8_matmul_vmem_bytes/bm{bm}_bn{bn}_bk{bk}",
                     vmem))
    return rows


def interpret_check_rows():
    """Tiny correctness re-check so `benchmarks.run` exercises kernels."""
    import jax.numpy as jnp

    from repro import attention as ATT
    from repro.kernels.int8_matmul.ops import int8_matmul
    from repro.kernels.int8_matmul.ref import int8_matmul_ref
    from repro.kernels.ita_attention import ref as AR

    rng = np.random.default_rng(0)
    x = rng.integers(-128, 128, (64, 128), dtype=np.int8)
    w = rng.integers(-128, 128, (128, 64), dtype=np.int8)
    mult = np.float32(0.001)
    out = int8_matmul(jnp.asarray(x), jnp.asarray(w), None, mult,
                      block_m=32, block_n=32, block_k=64)
    ref = int8_matmul_ref(jnp.asarray(x), jnp.asarray(w),
                          jnp.zeros((64,), jnp.int32),
                          jnp.broadcast_to(mult, (64,)))
    ok_mm = bool(jnp.all(out == ref))

    q = rng.integers(-128, 128, (1, 2, 64, 32), dtype=np.int8)
    k = rng.integers(-128, 128, (1, 2, 128, 32), dtype=np.int8)
    v = rng.integers(-128, 128, (1, 2, 128, 32), dtype=np.int8)
    s = np.float32(0.05)
    o = ATT.dispatch(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        spec=ATT.AttentionSpec(mode="prefill", impl="ita", layout="bhsd",
                               out_dtype="int8"),
        scales=ATT.QuantScales.per_tensor(s, s_out=np.float32(0.02)),
        backend="ita_onepass_pallas", block_q=32, block_kv=64)
    ref2 = AR.ita_attention_stream_ref(
        jnp.asarray(q.reshape(2, 64, 32)), jnp.asarray(k.reshape(2, 128, 32)),
        jnp.asarray(v.reshape(2, 128, 32)),
        np.float32(s * s / (np.sqrt(32) * 0.021660849392498294)),
        np.float32(s / 0.02), 128, causal=True, block_kv=64)
    ok_att = bool(jnp.all(o.reshape(2, 64, 32) == ref2))
    return [("kernels/int8_matmul_exact_vs_ref", int(ok_mm)),
            ("kernels/ita_attention_exact_vs_ref", int(ok_att))]


def _attention_vmem(bq, bkv, d):
    """VMEM working set (bytes) of one fused-attention grid step."""
    return bq * d + 2 * bkv * d + bq * d * 4 + 2 * bq * 4 + bq * bkv * 4


def _matmul_vmem(bm, bn, bk):
    """VMEM working set (bytes) of one int8-matmul grid step."""
    return bm * bk + bk * bn + bm * bn * 4


def sweep_rows(seq=256, d=64, heads=2, iters=3):
    """(block_q, block_kv) grid over the fused backends.

    Wall numbers are CPU-interpret (structure, not silicon); the VMEM
    column is platform-independent and is what actually picks the
    defaults: the largest block pair whose working set stays well inside
    a TPU core's VMEM while keeping the grid deep enough to pipeline.
    """
    import jax
    import jax.numpy as jnp

    from repro import attention as ATT

    rng = np.random.default_rng(0)
    s = np.float32(0.05)
    scales = ATT.QuantScales.per_tensor(s, s_out=np.float32(0.02))
    q = jnp.asarray(rng.integers(-128, 128, (1, heads, seq, d),
                                 dtype=np.int8))
    q1 = q[:, :, :1]
    kv = jnp.asarray(rng.integers(-128, 128, (1, heads, seq, d),
                                  dtype=np.int8))
    pre = ATT.AttentionSpec(mode="prefill", impl="ita", layout="bhsd",
                            out_dtype="int8")
    dec = ATT.AttentionSpec(mode="decode", impl="ita", layout="bhsd",
                            out_dtype="int8", q_len=1)

    def timed(fn):
        jax.block_until_ready(fn())            # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters * 1e6

    rows = []
    for bq in (32, 64, 128):
        for bkv in (32, 64, 128):
            if seq % bq or seq % bkv:
                continue
            us = timed(lambda: ATT.dispatch(
                q, kv, kv, spec=pre, scales=scales,
                backend="ita_onepass_pallas", block_q=bq, block_kv=bkv))
            rows.append((f"kernels/sweep_onepass/bq{bq}_bkv{bkv}",
                         us, _attention_vmem(bq, bkv, d)))
    for bkv in (32, 64, 128):
        if seq % bkv:
            continue
        us = timed(lambda: ATT.dispatch(
            q1, kv, kv, spec=dec, scales=scales, q_offset=seq - 1,
            kv_len=seq, backend="ita_decode_pallas", block_kv=bkv))
        rows.append((f"kernels/sweep_decode/bkv{bkv}",
                     us, _attention_vmem(8, bkv, d)))

    # int8 matmul (block_m, block_n, block_k) column of the same grid run
    # — the sweep behind BLOCK_DEFAULTS["int8_matmul"]
    from repro.kernels.int8_matmul.ops import int8_matmul
    m, k_dim, n = 256, 256, 256
    x = jnp.asarray(rng.integers(-128, 128, (m, k_dim), dtype=np.int8))
    w = jnp.asarray(rng.integers(-128, 128, (k_dim, n), dtype=np.int8))
    mult = np.float32(0.001)
    for bm in (64, 128, 256):
        for bn in (64, 128):
            for bk in (64, 128, 256):
                us = timed(lambda: int8_matmul(x, w, None, mult, block_m=bm,
                                               block_n=bn, block_k=bk))
                rows.append((f"kernels/sweep_int8_matmul/"
                             f"bm{bm}_bn{bn}_bk{bk}",
                             us, _matmul_vmem(bm, bn, bk)))
    return rows


def main():
    for name, val in vmem_rows() + interpret_check_rows():
        print(f"{name},0,{val}")
    if bool(int(os.environ.get("ITA_BENCH_SWEEP", "0"))):
        from repro.kernels.common import BLOCK_DEFAULTS
        for name, us, vmem in sweep_rows():
            print(f"{name},{us:.1f},{vmem}")
        for backend, blocks in BLOCK_DEFAULTS.items():
            print(f"kernels/block_default/{backend},0,"
                  + "_".join(str(x) for x in blocks))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--sweep", action="store_true",
                    help="run the (block_q, block_kv) grid behind "
                         "kernels.common.BLOCK_DEFAULTS")
    if ap.parse_args().sweep:
        os.environ["ITA_BENCH_SWEEP"] = "1"
    main()
