"""Paper §III (dataflow bandwidth): weight-stationary vs output-stationary
bytes/cycle — the paper's equation reproduced with ITA's parameters
(N=16 PEs, M=64-wide dots, D=24-bit partials), plus the TPU analogue:
HBM bytes moved by the two Pallas matmul schedules as a function of the
weight-reuse block size (the paper's "weights reused M times").
"""


def ita_bandwidth_bits(n=16, m=64, d=24):
    ws = 8 * (m + 3 * n) + 2 * n * d          # weight stationary (paper)
    os_ = 8 * (n * m + 3 * n) + 2 * n * d     # output stationary (paper)
    return ws, os_


def pallas_traffic_bytes(mm, kk, nn, bm, bn, bk):
    """HBM traffic model for the int8 matmul kernel at (M,K,N) with blocks
    (bm,bn,bk): weight tile fetched once per (m-block, n, k), i.e. reused
    over bm rows — ITA's M-fold reuse ≙ bm."""
    x_reads = mm * kk * (nn // bn)            # x streamed per n-block
    w_reads = kk * nn * (mm // bm)            # weights re-fetched per m-block
    out_writes = mm * nn
    return x_reads + w_reads + out_writes


def main():
    ws, os_ = ita_bandwidth_bits()
    print(f"dataflow/ita_paper_ws_bits_per_cycle,0,{ws}")
    print(f"dataflow/ita_paper_os_bits_per_cycle,0,{os_}")
    print(f"dataflow/ita_paper_saving,0,{os_ / ws:.3f}")

    # TPU analogue: 4096x4096 weight, 1M activations rows (qwen2-ish layer)
    mm, kk, nn = 65536, 4096, 4096
    for bm in (128, 256, 1024, 4096):
        t = pallas_traffic_bytes(mm, kk, nn, bm, 128, 512)
        print(f"dataflow/pallas_ws_traffic_bytes/bm{bm},0,{t}")
    base = pallas_traffic_bytes(mm, kk, nn, 128, 128, 512)
    best = pallas_traffic_bytes(mm, kk, nn, 4096, 128, 512)
    print(f"dataflow/pallas_reuse_saving,0,{base / best:.3f}")


if __name__ == "__main__":
    main()
